"""Jitted public wrapper for engine-backed flash attention.

Accepts standard (B, H, T, D) layouts, handles GQA head mapping, pads
sequence lengths to block multiples (mask-correct via ``kv_len``),
resolves ``schedule="auto"`` through ``policy.choose_attention_schedule``
(carry for row-saturated shapes, split-KV decoupled for long-KV
decode/scoring), and interpret-mode fallback off-TPU.

``flash_attention`` is differentiable via ``jax.custom_vjp``: the
forward rule reruns the fold with ``return_stats=True`` to save the
``(m, l)`` row statistics, the backward rule derives the
``delta = rowsum(dO ⊙ O)`` precompute (one tiny row fold) and runs the
two backward engine folds (dq over KV blocks, dk/dv over the transposed
q-major layout) under the SAME resolved schedule and causal-aware KV
bounds as the forward — so training through ``impl="flash"`` is a peer
of the autodiff-able dense/blockwise references.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.scan import policy
from repro.core.scan.assoc import NEG_INF
from repro.kernels.flash_attention.flash_attention import (
    default_kv_split_target, flash_attention_bwd_kernel,
    flash_attention_kernel)

SCHEDULES = ("carry", "decoupled")
RESOLVABLE = SCHEDULES + ("auto",)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _tiles(Tq: int, Tk: int, block_q: int, block_k: int):
    """The (bq, bk, nq) tiling the kernel will ACTUALLY use — the single
    source of truth shared by the impl and the schedule resolver, so the
    policy's chunks-per-core test never drifts from the real grid."""
    bq = min(block_q, _round_up(Tq, 8))
    bk = min(block_k, _round_up(Tk, 128))
    return bq, bk, max(-(-Tq // bq), 1)


def _decoupled_padding(Tk: int, bk: int, kv_splits: "int | None"):
    """(pad_k, splits) for the split-KV fold: pad the KV axis up to a
    multiple of ``splits`` blocks so the chunk count is always achieved.
    Without this, a prime block count (500k context -> 3907 blocks) has
    no divisor <= target and the 'split-KV' launch would silently
    degenerate to one serial chunk; the masked tail (``kv_len``) makes
    identity padding free."""
    nk = _round_up(Tk, bk) // bk
    target = kv_splits if kv_splits is not None \
        else default_kv_split_target()
    splits = max(1, min(int(target), nk))
    return _round_up(nk, splits) * bk - Tk, splits


class FlashConfig(NamedTuple):
    """Hashable static configuration shared by the forward and backward
    rules of the ``custom_vjp`` (``schedule`` arrives RESOLVED)."""

    scale: float
    causal: bool
    window: Optional[int]
    softcap: Optional[float]
    block_q: int
    block_k: int
    schedule: str
    kv_splits: Optional[int]
    use_kv_bounds: bool
    interpret: bool


def _padding(Tq: int, Tk: int, cfg: FlashConfig):
    """(bq, bk, pad_q, pad_k, kv_splits) for this shape and schedule."""
    bq, bk, _ = _tiles(Tq, Tk, cfg.block_q, cfg.block_k)
    pad_q = (-Tq) % bq
    if cfg.schedule == "decoupled":
        pad_k, kv_splits = _decoupled_padding(Tk, bk, cfg.kv_splits)
    else:
        pad_k, kv_splits = (-Tk) % bk, cfg.kv_splits
    return bq, bk, pad_q, pad_k, kv_splits


def _flatten_pad(q, k, v, pad_q, pad_k):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    qf = q.reshape(B * Hq, Tq, D)
    kf = k.reshape(B * Hkv, Tk, D)
    vf = v.reshape(B * Hkv, Tk, D)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    return qf, kf, vf


def _kernel_kwargs(cfg: FlashConfig, Tk, bq, bk, kv_splits, group):
    return dict(group=group, scale=cfg.scale, causal=cfg.causal,
                window=cfg.window, softcap=cfg.softcap, kv_len=Tk,
                block_q=bq, block_k=bk, schedule=cfg.schedule,
                kv_splits=kv_splits, use_kv_bounds=cfg.use_kv_bounds,
                interpret=cfg.interpret)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _impl(q, k, v, cfg: FlashConfig):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    bq, bk, pad_q, pad_k, kv_splits = _padding(Tq, Tk, cfg)
    qf, kf, vf = _flatten_pad(q, k, v, pad_q, pad_k)
    out = flash_attention_kernel(
        qf, kf, vf, **_kernel_kwargs(cfg, Tk, bq, bk, kv_splits, Hq // Hkv))
    return out[:, :Tq].reshape(B, Hq, Tq, D)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _impl_stats(q, k, v, cfg: FlashConfig):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    bq, bk, pad_q, pad_k, kv_splits = _padding(Tq, Tk, cfg)
    qf, kf, vf = _flatten_pad(q, k, v, pad_q, pad_k)
    out, m, l = flash_attention_kernel(
        qf, kf, vf, return_stats=True,
        **_kernel_kwargs(cfg, Tk, bq, bk, kv_splits, Hq // Hkv))
    return (out[:, :Tq].reshape(B, Hq, Tq, D),
            m[:, :Tq].reshape(B, Hq, Tq, 1),
            l[:, :Tq].reshape(B, Hq, Tq, 1))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _impl_bwd(q, k, v, out, m, l, g, cfg: FlashConfig):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    bq, bk, pad_q, pad_k, kv_splits = _padding(Tq, Tk, cfg)
    qf, kf, vf = _flatten_pad(q, k, v, pad_q, pad_k)
    # The small precompute fold: delta = rowsum(dO ⊙ O), one f32 scalar
    # per query row — the shared term of the softmax VJP.
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1, keepdims=True)

    def qrow(x, fill):
        x = x.reshape(B * Hq, Tq, x.shape[-1])
        if pad_q:
            x = jnp.pad(x, ((0, 0), (0, pad_q), (0, 0)),
                        constant_values=fill)
        return x

    # Padded q rows carry dO = 0 and delta = 0, so every term they feed
    # (dq, and their dk/dv contributions) vanishes — PROVIDED their
    # recomputed p is finite: m pads to +1e30 (not the NEG_INF identity,
    # under which exp(s - m) on the padded rows' causally-live columns
    # would overflow to inf and poison the dk/dv sums with inf·0 NaNs),
    # making p underflow to exactly 0 there.
    dq, dk, dv = flash_attention_bwd_kernel(
        qf, kf, vf, qrow(g, 0), qrow(m, -NEG_INF), qrow(l, 0),
        qrow(delta, 0),
        **_kernel_kwargs(cfg, Tk, bq, bk, kv_splits, Hq // Hkv))
    return (dq[:, :Tq].reshape(B, Hq, Tq, D).astype(q.dtype),
            dk[:, :Tk].reshape(B, Hkv, Tk, D).astype(k.dtype),
            dv[:, :Tk].reshape(B, Hkv, Tk, D).astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, cfg: FlashConfig):
    return _impl(q, k, v, cfg)


def _flash_fwd_rule(q, k, v, cfg: FlashConfig):
    out, m, l = _impl_stats(q, k, v, cfg)
    return out, (q, k, v, out, m, l)


def _flash_bwd_rule(cfg: FlashConfig, res, g):
    q, k, v, out, m, l = res
    return _impl_bwd(q, k, v, out, m, l, g, cfg)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def resolved_attention_schedule(
    q_shape, kv_len: int, block_q: int = 128, block_k: int = 128,
    schedule: str = "auto",
) -> str:
    """The fold schedule a (B, H, Tq, D) attention will actually run.

    Mirrors ``flash_attention``'s tiling: the carry grid parallelizes
    (B·H, q-blocks) rows, so the policy's batch is the number of
    independent fold chains and its chunk length the real KV block.
    Exposed so consumers (serve tests, benchmarks) can assert the
    long-KV decode/scoring class lands on the split-KV form. The
    backward folds inherit the forward's resolution — one choice per
    ``custom_vjp`` instance.
    """
    if schedule not in RESOLVABLE:
        raise ValueError(
            f"unknown attention schedule {schedule!r}; one of {RESOLVABLE}")
    if schedule != "auto":
        return schedule
    B, Hq, Tq, _ = q_shape
    _, bk, nq = _tiles(Tq, kv_len, block_q, block_k)
    return policy.choose_attention_schedule(
        B * Hq * nq, kv_len, block_elems=bk)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: "float | None" = None,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    schedule: str = "auto",
    kv_splits: "int | None" = None,
    use_kv_bounds: bool = True,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Flash attention over (B, H, T, D) tensors with GQA kv heads.

    ``schedule`` picks the fold organization (carry|decoupled|auto — see
    ``core/scan/policy.choose_attention_schedule``); ``interpret=None``
    auto-selects compiled on TPU, interpret elsewhere. Differentiable:
    ``jax.grad`` runs the flash backward as engine folds (same schedule,
    same KV bounds) instead of detouring through the jnp references.
    ``use_kv_bounds=False`` disables the causal-aware cell skipping
    (bitwise-identical results either way — the knob exists for the
    parity tests and for hardware A/B measurement).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if interpret is None:
        interpret = not _on_tpu()
    schedule = resolved_attention_schedule(
        q.shape, k.shape[2], block_q, block_k, schedule)
    cfg = FlashConfig(
        scale=float(scale), causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, schedule=schedule,
        kv_splits=kv_splits, use_kv_bounds=use_kv_bounds,
        interpret=interpret)
    return _flash(q, k, v, cfg)
