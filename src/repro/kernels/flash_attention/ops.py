"""Jitted public wrapper for engine-backed flash attention.

Accepts standard (B, H, T, D) layouts, handles GQA head mapping, pads
sequence lengths to block multiples (mask-correct via ``kv_len``),
resolves ``schedule="auto"`` through ``policy.choose_attention_schedule``
(carry for row-saturated shapes, split-KV decoupled for long-KV
decode/scoring), and interpret-mode fallback off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scan import policy
from repro.kernels.flash_attention.flash_attention import (
    default_kv_split_target, flash_attention_kernel)

SCHEDULES = ("carry", "decoupled")
RESOLVABLE = SCHEDULES + ("auto",)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _tiles(Tq: int, Tk: int, block_q: int, block_k: int):
    """The (bq, bk, nq) tiling the kernel will ACTUALLY use — the single
    source of truth shared by ``_impl`` and the schedule resolver, so the
    policy's chunks-per-core test never drifts from the real grid."""
    bq = min(block_q, _round_up(Tq, 8))
    bk = min(block_k, _round_up(Tk, 128))
    return bq, bk, max(-(-Tq // bq), 1)


def _decoupled_padding(Tk: int, bk: int, kv_splits: "int | None"):
    """(pad_k, splits) for the split-KV fold: pad the KV axis up to a
    multiple of ``splits`` blocks so the chunk count is always achieved.
    Without this, a prime block count (500k context -> 3907 blocks) has
    no divisor <= target and the 'split-KV' launch would silently
    degenerate to one serial chunk; the masked tail (``kv_len``) makes
    identity padding free."""
    nk = _round_up(Tk, bk) // bk
    target = kv_splits if kv_splits is not None \
        else default_kv_split_target()
    splits = max(1, min(int(target), nk))
    return _round_up(nk, splits) * bk - Tk, splits


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "softcap",
        "block_q", "block_k", "schedule", "kv_splits", "interpret",
    ),
)
def _impl(q, k, v, scale, causal, window, softcap, block_q, block_k,
          schedule, kv_splits, interpret):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    bq, bk, _ = _tiles(Tq, Tk, block_q, block_k)
    pad_q = (-Tq) % bq
    if schedule == "decoupled":
        pad_k, kv_splits = _decoupled_padding(Tk, bk, kv_splits)
    else:
        pad_k = (-Tk) % bk

    qf = q.reshape(B * Hq, Tq, D)
    kf = k.reshape(B * Hkv, Tk, D)
    vf = v.reshape(B * Hkv, Tk, D)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_kernel(
        qf, kf, vf,
        group=group, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_len=Tk, block_q=bq, block_k=bk,
        schedule=schedule, kv_splits=kv_splits, interpret=interpret,
    )
    return out[:, :Tq].reshape(B, Hq, Tq, D)


def resolved_attention_schedule(
    q_shape, kv_len: int, block_q: int = 128, block_k: int = 128,
    schedule: str = "auto",
) -> str:
    """The fold schedule a (B, H, Tq, D) attention will actually run.

    Mirrors ``flash_attention``'s tiling: the carry grid parallelizes
    (B·H, q-blocks) rows, so the policy's batch is the number of
    independent fold chains and its chunk length the real KV block.
    Exposed so consumers (serve tests, benchmarks) can assert the
    long-KV decode/scoring class lands on the split-KV form.
    """
    if schedule not in RESOLVABLE:
        raise ValueError(
            f"unknown attention schedule {schedule!r}; one of {RESOLVABLE}")
    if schedule != "auto":
        return schedule
    B, Hq, Tq, _ = q_shape
    _, bk, nq = _tiles(Tq, kv_len, block_q, block_k)
    return policy.choose_attention_schedule(
        B * Hq * nq, kv_len, block_elems=bk)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: "float | None" = None,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    schedule: str = "auto",
    kv_splits: "int | None" = None,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Flash attention over (B, H, T, D) tensors with GQA kv heads.

    ``schedule`` picks the fold organization (carry|decoupled|auto — see
    ``core/scan/policy.choose_attention_schedule``); ``interpret=None``
    auto-selects compiled on TPU, interpret elsewhere.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if interpret is None:
        interpret = not _on_tpu()
    schedule = resolved_attention_schedule(
        q.shape, k.shape[2], block_q, block_k, schedule)
    return _impl(q, k, v, scale, causal, window, softcap,
                 block_q, block_k, schedule, kv_splits, interpret)
