"""Jitted public wrapper for flash attention.

Accepts standard (B, H, T, D) layouts, handles GQA head mapping, pads
sequence lengths to block multiples (mask-correct via ``kv_len``), and
interpret-mode fallback off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "softcap",
        "block_q", "block_k", "interpret",
    ),
)
def _impl(q, k, v, scale, causal, window, softcap, block_q, block_k, interpret):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    bq = min(block_q, _round_up(Tq, 8))
    bk = min(block_k, _round_up(Tk, 128))
    pad_q = (-Tq) % bq
    pad_k = (-Tk) % bk

    qf = q.reshape(B * Hq, Tq, D)
    kf = k.reshape(B * Hkv, Tk, D)
    vf = v.reshape(B * Hkv, Tk, D)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_kernel(
        qf, kf, vf,
        group=group, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_len=Tk, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    return out[:, :Tq].reshape(B, Hq, Tq, D)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: "float | None" = None,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Flash attention over (B, H, T, D) tensors with GQA kv heads."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if interpret is None:
        interpret = not _on_tpu()
    return _impl(q, k, v, scale, causal, window, softcap,
                 block_q, block_k, interpret)
