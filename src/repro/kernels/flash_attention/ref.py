"""Oracles for flash attention.

``mha_ref``       — dense softmax attention (ground truth, O(T²) memory).
``blockwise_ref`` — jnp lax.scan over KV blocks with the online-softmax
                    monoid: autodiff-able, O(T·block) memory. Used by the
                    training path; also validates that the kernel's scan
                    structure matches a pure-jnp formulation.

Fully-masked rows (q positions past ``kv_len + window``) emit EXACTLY 0
with zero gradients: probabilities are zeroed at masked columns and the
normalizer divide is guarded. The unguarded ``softmax(NEG_INF row)``
form instead yields a uniform average over the masked columns — an
output that depends on how many padded/masked columns the formulation
happens to visit, and that under autodiff leaks a nonzero cotangent
into ``v``. The guard keeps both references well-defined baselines for
the kernel gradient-parity wall and is bitwise-free for live rows
(``exp(NEG_INF - m)`` underflows to exactly 0 there).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scan.assoc import NEG_INF


def _mask(rows, cols, kv_len, causal, window):
    m = cols < kv_len
    if causal:
        m &= cols <= rows
    if window is not None:
        m &= cols > rows - window
    return m


def masked_softmax(s, mask):
    """The repo-wide zeroed-probability softmax over the last axis.

    Masked logits see ``NEG_INF`` for the row max, masked probabilities
    are EXACTLY 0 (bitwise-neutral for live rows, where the exp already
    underflows to 0), and the guarded divide sends fully-masked rows to
    0 instead of a uniform average. Every attention implementation —
    dense layer path, these oracles, the kernel transform — states its
    softmax this way so the gradient-parity wall and the causal-aware
    KV bound's bitwise identity share one convention.
    """
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    return p / jnp.where(l == 0.0, 1.0, l)


def mha_ref(
    q, k, v, *, group=1, scale, causal=True, window=None, softcap=None,
    kv_len=None,
):
    """Dense attention over (BH, Tq, d) / (BHkv, Tk, d)."""
    BH, Tq, d = q.shape
    BHkv, Tk, _ = k.shape
    kv_len = Tk if kv_len is None else kv_len
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(Tq)[:, None]
    cols = jnp.arange(Tk)[None, :]
    p = masked_softmax(s, _mask(rows, cols, kv_len, causal, window)[None])
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def blockwise_ref(
    q, k, v, *, group=1, scale, causal=True, window=None, softcap=None,
    kv_len=None, block_k=512, unroll=False,
):
    """Online-softmax attention as an explicit lax.scan over KV blocks."""
    BH, Tq, d = q.shape
    BHkv, Tk, _ = k.shape
    kv_len = Tk if kv_len is None else kv_len
    if Tk % block_k:
        pad = -Tk % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        Tk = Tk + pad
    nk = Tk // block_k
    kb = k.reshape(BHkv, nk, block_k, d).transpose(1, 0, 2, 3)
    vb = v.reshape(BHkv, nk, block_k, d).transpose(1, 0, 2, 3)
    qf = q.astype(jnp.float32)
    rows = jnp.arange(Tq)[:, None]

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kj, kblk, vblk = blk
        kr = jnp.repeat(kblk, group, axis=0).astype(jnp.float32)
        vr = jnp.repeat(vblk, group, axis=0).astype(jnp.float32)
        s = jnp.einsum("hqd,hkd->hqk", qf, kr) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = kj * block_k + jnp.arange(block_k)[None, :]
        mask = _mask(rows, cols, kv_len, causal, window)[None]
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("hqk,hkd->hqd", p, vr)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((BH, Tq, 1), NEG_INF, jnp.float32),
        jnp.zeros((BH, Tq, 1), jnp.float32),
        jnp.zeros((BH, Tq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(nk), kb, vb),
                                  unroll=True if unroll else 1)
    safe = jnp.where(l == 0.0, 1.0, l)
    return (acc / safe).astype(q.dtype)


def banded_ref(
    q, k, v, *, scale, window, softcap=None, kv_len=None,
    block_q=512, block_k=512, unroll=False,
):
    """Sliding-window attention touching ONLY the in-window KV band.

    Beyond-paper optimization (EXPERIMENTS.md §Perf): the plain blockwise
    scan walks ALL Tk/block_k KV blocks per query and relies on masking;
    for a local (windowed) layer the live band is just ``window + bq``
    wide. We slice that band per query block — compute and bytes drop by
    ~Tk / (window + bq), e.g. 21x for gemma3's 1024-window local layers
    at 32k context. Causality is implied (band ends at the query block's
    last row); front zero-padding keeps the dynamic slice in bounds.

    LAYOUT: q (B, H, Tq, d), k/v (B, Hkv, Tk, d) — batch and head axes
    stay SEPARATE so GSPMD sharding (batch→data, heads→model) propagates
    without the all-gathering (B·H) merge reshape (measured regression,
    EXPERIMENTS.md §Perf iteration 2).
    """
    B, H, Tq, d = q.shape
    _, Hkv, Tk, _ = k.shape
    g = H // Hkv
    kv_len = Tk if kv_len is None else kv_len
    bq = bk = min(block_q, block_k)  # equal blocks: static band indexing
    if Tq % bq:
        raise ValueError(f"Tq={Tq} must divide block {bq}")
    nq = Tq // bq
    # Band of nband KV blocks per query block: {i-nband+1, ..., i}.
    nband = min((window - 1) // bk + 2, nq)
    L = nband * bk

    # STATIC shifted stacks instead of per-block dynamic slices: the VJP
    # of a dynamic slice materializes a full-size zero buffer PER BLOCK
    # (measured +8% memory, §Perf iteration); static slicing keeps the
    # cotangent as nband cheap pad-slice adds.
    front = (nband - 1) * bk
    kp = jnp.pad(k, ((0, 0), (0, 0), (front, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (front, 0), (0, 0)))
    # kb_stack[j] = blocks shifted by (nband-1-j): shape (B,Hkv,nq,bk,d)
    kb = jnp.stack([
        kp[:, :, j * bk: j * bk + Tq].reshape(B, Hkv, nq, bk, d)
        for j in range(nband)], axis=3)            # (B,Hkv,nq,nband,bk,d)
    vb = jnp.stack([
        vp[:, :, j * bk: j * bk + Tq].reshape(B, Hkv, nq, bk, d)
        for j in range(nband)], axis=3)
    kb = kb.reshape(B, Hkv, nq, L, d).transpose(2, 0, 1, 3, 4)
    vb = vb.reshape(B, Hkv, nq, L, d).transpose(2, 0, 1, 3, 4)
    qb = q.reshape(B, Hkv, g, nq, bq, d).transpose(3, 0, 1, 2, 4, 5)

    def one_block(_, blk):
        i, qi, ki, vi = blk                        # ki/vi: (B,Hkv,L,d)
        qs = i * bq
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                       ki.astype(jnp.float32)) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = qs + jnp.arange(bq)[:, None]
        cols = qs + bq - L + jnp.arange(L)[None, :]
        m = ((cols >= 0) & (cols < kv_len) & (cols <= rows)
             & (cols > rows - window))[None, None, None]
        p = masked_softmax(s, m)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, ob = jax.lax.scan(one_block, None, (jnp.arange(nq), qb, kb, vb),
                         unroll=True if unroll else 1)
    # (nq, B, Hkv, g, bq, d) -> (B, H, Tq, d)
    return ob.transpose(1, 2, 3, 0, 4, 5).reshape(B, H, Tq, d)
