"""Pure-jnp oracle for the SSM affine-scan kernel: sequential lax.scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1 of (B, T, D); h_{-1} = 0."""
    acc = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16) else a.dtype
    a32, b32 = a.astype(acc), b.astype(acc)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    def per_batch(a1, b1):
        h0 = jnp.zeros(a1.shape[-1], acc)
        _, hs = jax.lax.scan(step, h0, (a1, b1))
        return hs

    return jax.vmap(per_batch)(a32, b32).astype(b.dtype)
