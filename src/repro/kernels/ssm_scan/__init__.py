from repro.kernels.ssm_scan.ops import (resolved_schedule, ssm_scan,
                                        ssm_scan_decoupled, ssm_scan_kernel)
from repro.kernels.ssm_scan.ref import ssm_scan_ref

__all__ = ["resolved_schedule", "ssm_scan", "ssm_scan_ref",
           "ssm_scan_decoupled", "ssm_scan_kernel"]
