from repro.kernels.ssm_scan.decoupled import ssm_scan_decoupled
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan_kernel

__all__ = ["ssm_scan", "ssm_scan_ref", "ssm_scan_decoupled",
           "ssm_scan_kernel"]
