"""Decoupled reduce-then-scan AFFINE scan (SSM recurrence) — time across
cores.

The carry-chain kernel (``ssm_scan.py``) serializes the time axis: grid
``(B, D-blocks, T-blocks)`` with time ``"arbitrary"``, so a (B=1, huge T)
decode/prefill recurrence runs on one core. Decoupled organization
(paper Observation 3, SIMD2-P) over the affine monoid:

  pass 1b  parallel grid emits each time-chunk's composed affine map
           ``(A, B) = (prod a, cumulative b)`` — the last row of the
           in-chunk Hillis–Steele pair scan.
  combine  sequential exclusive chain ``h' = B + A * h`` over the
           (batch, chunks, D) chunk maps — the same expression order as
           the carry kernel's state update (bit-identical).
  pass 2   parallel grid redoes the in-chunk pair scan and fuses the
           incoming state into the writeback ``h_t = B_t + A_t * h_in``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params
from repro.kernels.ssm_scan.ssm_scan import _affine_log_scan


def _totals_kernel(a_ref, b_ref, tot_a_ref, tot_b_ref, *, acc_dtype):
    a = a_ref[0].astype(acc_dtype)  # (bt, bd)
    b = b_ref[0].astype(acc_dtype)
    A, B = _affine_log_scan(a, b, axis=0)
    tot_a_ref[0] = A[-1:, :]
    tot_b_ref[0] = B[-1:, :]


def _scan_kernel(a_ref, b_ref, h_ref, o_ref, *, acc_dtype):
    a = a_ref[0].astype(acc_dtype)
    b = b_ref[0].astype(acc_dtype)
    A, B = _affine_log_scan(a, b, axis=0)
    h_in = h_ref[0]  # (1, bd): state entering the chunk
    o_ref[0] = (B + A * h_in).astype(o_ref.dtype)


def _exclusive_chain(tot_a: jax.Array, tot_b: jax.Array) -> jax.Array:
    """Exclusive affine chain over (B, chunks, D) maps along axis 1."""

    def step(h, ab):
        a, b = ab
        return b + a * h, h  # same float-op order as the carry kernel

    zero = jnp.zeros_like(tot_b[:, 0])
    _, hs = jax.lax.scan(
        step, zero,
        (jnp.moveaxis(tot_a, 1, 0), jnp.moveaxis(tot_b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def ssm_scan_decoupled(
    a: jax.Array,
    b: jax.Array,
    *,
    block_t: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Decoupled affine scan along axis 1 of (B, T, D) inputs.

    Same caller contract as ``ssm_scan_kernel``; bit-identical results.
    """
    if a.shape != b.shape or a.ndim != 3:
        raise ValueError(
            f"expect matching (B, T, D) inputs, got {a.shape} {b.shape}")
    B, T, D = a.shape
    if T % block_t or D % block_d:
        raise ValueError(f"({T}, {D}) not divisible by ({block_t}, {block_d})")
    acc_dtype = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16) \
        else a.dtype
    chunks = T // block_t
    grid = (B, D // block_d, chunks)
    spec = pl.BlockSpec((1, block_t, block_d), lambda i, d, t: (i, t, d))
    tspec = pl.BlockSpec((1, 1, block_d), lambda i, d, t: (i, t, d))
    par = compiler_params(
        dimension_semantics=("parallel", "parallel", "parallel"))

    tot_a, tot_b = pl.pallas_call(
        functools.partial(_totals_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[tspec, tspec],
        out_shape=[
            jax.ShapeDtypeStruct((B, chunks, D), acc_dtype),
            jax.ShapeDtypeStruct((B, chunks, D), acc_dtype),
        ],
        compiler_params=par,
        interpret=interpret,
        name="ssm_scan_totals",
    )(a, b)

    h_in = _exclusive_chain(tot_a, tot_b)

    return pl.pallas_call(
        functools.partial(_scan_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[spec, spec, tspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, b.dtype),
        compiler_params=par,
        interpret=interpret,
        name="ssm_scan_apply",
    )(a, b, h_in)
