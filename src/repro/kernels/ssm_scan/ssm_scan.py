"""Pallas TPU kernel: chunked affine scan — SSM recurrences as prefix sums.

Computes, along the time axis,

    h_t = a_t * h_{t-1} + b_t        (elementwise over the channel axis)

which is the inclusive scan of the *affine monoid* (see
``repro.core.scan.assoc.AFFINE``). Diagonal SSM recurrences (Mamba2 decay,
xLSTM forget/input gates, RetNet-style linear attention denominators) all
have this form.

Paper mapping — this kernel is the paper's two techniques composed, with a
richer operator:

  * §3.2 *vertical SIMD*: channels are the SIMD lanes. Each lane carries an
    independent recurrence — the work-efficient O(n) schedule with no
    horizontal interaction, which on TPU is the natural layout (channels on
    the 128-lane axis), not a gather/scatter penalty (Observation 5
    inverts).
  * §2.2 *cache-friendly partitioning*: the time axis is cut into
    VMEM-sized chunks; within a chunk a log-step Hillis–Steele scan of the
    (a, b) pairs runs in registers; the inter-chunk state is the grid-
    carried `sums` array.

Grid: (batch, channel_blocks, time_blocks) — time innermost so the carry in
VMEM scratch chains across time blocks of one (batch, channel) stripe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params


def _affine_log_scan(a: jax.Array, b: jax.Array, axis: int):
    """In-block inclusive scan of affine pairs (Hillis–Steele, paper §3.1).

    combine(left, right) = (a_l·a_r, a_r·b_l + b_r); shifts fill with the
    identity (1, 0).
    """
    n = a.shape[axis]
    k = 1
    while k < n:
        a_sh = _shift(a, k, axis, fill=1.0)
        b_sh = _shift(b, k, axis, fill=0.0)
        b = a * b_sh + b
        a = a * a_sh
        k *= 2
    return a, b


def _shift(x: jax.Array, k: int, axis: int, fill: float) -> jax.Array:
    pad = [(0, 0)] * x.ndim
    pad[axis] = (k, 0)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, x.shape[axis])
    return jnp.pad(x, pad, constant_values=fill)[tuple(sl)]


def _kernel(a_ref, b_ref, o_ref, carry_ref, *, acc_dtype):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)  # h before the sequence

    a = a_ref[0].astype(acc_dtype)  # (bt, bd)
    b = b_ref[0].astype(acc_dtype)
    # Pass 1 (in VMEM): cumulative affine maps within the chunk.
    A, B = _affine_log_scan(a, b, axis=0)
    # Pass 2 (fused): apply the carried state h ⇒ h_t = B_t + A_t · h_in.
    h_in = carry_ref[...]  # (1, bd)
    out = B + A * h_in
    o_ref[0] = out.astype(o_ref.dtype)
    carry_ref[...] = out[-1:, :]  # the paper's `sums` update


def ssm_scan_kernel(
    a: jax.Array,
    b: jax.Array,
    *,
    block_t: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Affine scan along axis 1 of (B, T, D) inputs; returns h of same shape.

    Caller contract (see ops.py): T % block_t == 0 and D % block_d == 0.
    """
    if a.shape != b.shape or a.ndim != 3:
        raise ValueError(f"expect matching (B, T, D) inputs, got {a.shape} {b.shape}")
    B, T, D = a.shape
    if T % block_t or D % block_d:
        raise ValueError(f"({T}, {D}) not divisible by ({block_t}, {block_d})")
    acc_dtype = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16) else a.dtype
    grid = (B, D // block_d, T // block_t)
    spec = pl.BlockSpec((1, block_t, block_d), lambda i, d, t: (i, t, d))
    return pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), acc_dtype)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssm_scan",
    )(a, b)
