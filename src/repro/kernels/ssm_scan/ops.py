"""Jitted public wrapper for the SSM affine-scan kernel.

Pads T to a block multiple with the identity element (a=1, b=0) — identity
padding keeps the carried state unchanged, so results are exact after the
slice — and pads D with zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret")
)
def _impl(a, b, block_t, block_d, interpret):
    B, T, D = a.shape
    bt = min(block_t, _round_up(T, 8))
    bd = min(block_d, _round_up(D, 128))
    pad_t = (-T) % bt
    pad_d = (-D) % bd
    a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_d)), constant_values=1)
    b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d)))
    out = ssm_scan_kernel(a, b, block_t=bt, block_d=bd, interpret=interpret)
    return out[:, :T, :D]


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def ssm_scan(
    a: jax.Array,
    b: jax.Array,
    block_t: int = 256,
    block_d: int = 512,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Kernel-backed h_t = a_t ⊙ h_{t-1} + b_t over (B, T, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    return _impl(a, b, block_t, block_d, interpret)
