"""Jitted public wrapper for the SSM affine-scan kernels.

Pads T to a block multiple with the identity element (a=1, b=0) — identity
padding keeps the carried state unchanged, so results are exact after the
slice — and pads D with zeros.  ``schedule`` picks the grid organization
(see ``core/scan/policy``): the carry chain walks time sequentially per
(batch, channel) stripe; decoupled spreads time chunks across cores —
the B=1 long-context prefill/decode shape. Channels count as batch for
the policy rule (they are independent lanes the carry grid already
parallelizes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scan_blocked.ops import resolve_schedule
from repro.kernels.ssm_scan.decoupled import ssm_scan_decoupled
from repro.kernels.ssm_scan.ssm_scan import ssm_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret", "schedule")
)
def _impl(a, b, block_t, block_d, interpret, schedule):
    B, T, D = a.shape
    bt = min(block_t, _round_up(T, 8))
    bd = min(block_d, _round_up(D, 128))
    pad_t = (-T) % bt
    pad_d = (-D) % bd
    a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_d)), constant_values=1)
    b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d)))
    kernel = ssm_scan_decoupled if schedule == "decoupled" else ssm_scan_kernel
    out = kernel(a, b, block_t=bt, block_d=bd, interpret=interpret)
    return out[:, :T, :D]


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def ssm_scan(
    a: jax.Array,
    b: jax.Array,
    block_t: int = 256,
    block_d: int = 512,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> jax.Array:
    """Kernel-backed h_t = a_t ⊙ h_{t-1} + b_t over (B, T, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, T, D = a.shape
    # Mirror _impl's actual tiling: the carry grid already parallelizes
    # (B, D-blocks), so the policy's "batch" is the number of independent
    # carry chains, and its chunk length is the real time block.
    bt = min(block_t, _round_up(T, 8))
    bd = min(block_d, _round_up(D, 128))
    batch = B * max(-(-D // bd), 1)
    schedule = resolve_schedule(schedule, batch, T, bt)
    return _impl(a, b, block_t, block_d, interpret, schedule)
