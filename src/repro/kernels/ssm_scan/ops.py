"""Affine (SSM-recurrence) scan: the AFFINE registration of the engine.

Computes ``h_t = a_t * h_{t-1} + b_t`` along the time axis of (B, T, D)
inputs — the inclusive scan of ``core/scan/assoc.AFFINE_KERNEL`` run
through the monoid-generic engine on the Channels layout (time on
sublanes, channels on the 128-lane axis: the paper's §3.2 vertical SIMD,
which is the natural TPU layout rather than a gather penalty).

Pads T to a block multiple with the identity element (a=1, b=0) — identity
padding keeps the carried state unchanged, so results are exact after the
slice — and pads D with zeros. ``schedule`` picks the grid organization
(see ``core/scan/policy``): the carry chain walks time sequentially per
(batch, channel) stripe; decoupled/fused spread time chunks across cores
— the B=1 long-context prefill/decode shape; ``tree`` runs the Blelloch
sweep inside each time tile. Channel blocks count as batch for the
policy rule (they are independent stripes the carry grid already
parallelizes).

Differentiable: the gradient of the affine recurrence is ITSELF an
affine recurrence run backward — the adjoint satisfies
``λ_t = g_t + a_{t+1} · λ_{t+1}``, which after flipping the time axis is
the same ``h_t = a_t h_{t-1} + b_t`` form with the gates reversed and
rolled one step. The ``jax.custom_vjp`` therefore runs the backward
through the same jitted engine kernel as the forward (same schedule,
its own ``kernel.launch`` trace event) and reads the input gradients
off pointwise: ``db_t = λ_t``, ``da_t = λ_t · h_{t-1}``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import scan_engine
from repro.kernels.scan_engine import monoids, resolve_schedule


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret", "schedule")
)
def _impl(a, b, block_t, block_d, interpret, schedule):
    B, T, D = a.shape
    bt = min(block_t, _round_up(T, 8))
    bd = min(block_d, _round_up(D, 128))
    pad_t = (-T) % bt
    pad_d = (-D) % bd
    a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_d)), constant_values=1)
    b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d)))
    layout = scan_engine.Channels(B, T + pad_t, D + pad_d, bt, bd)
    out, = scan_engine.scan(
        (a, b), monoids.AFFINE, layout, schedule=schedule,
        interpret=interpret)
    return out[:, :T, :D]


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def resolved_schedule(shape, block_t: int = 256, block_d: int = 512,
                      schedule: str = "auto") -> str:
    """The schedule a (B, T, D) affine scan will actually run.

    Mirrors ``ssm_scan``'s tiling: the carry grid already parallelizes
    (B, D-blocks) stripes, so the policy's "batch" is the number of
    independent carry chains and its chunk length is the real time block.
    Exposed so consumers (serve engine tests, benchmarks) can assert the
    decode/prefill shape class lands on a parallel-sequence schedule.
    """
    B, T, D = shape
    bt = min(block_t, _round_up(T, 8))
    bd = min(block_d, _round_up(D, 128))
    batch = B * max(-(-D // bd), 1)
    return resolve_schedule(schedule, batch, T, bt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _ssm_vjp(a, b, block_t, block_d, interpret, schedule):
    return _impl(a, b, block_t, block_d, interpret, schedule)


def _ssm_fwd(a, b, block_t, block_d, interpret, schedule):
    h = _impl(a, b, block_t, block_d, interpret, schedule)
    # Residuals: the gates (backward recurrence coefficients) and the
    # forward states (da_t needs h_{t-1}) — no extra forward work.
    return h, (a, h)


def _ssm_bwd(block_t, block_d, interpret, schedule, residuals, g):
    a, h = residuals
    # Adjoint recurrence λ_t = g_t + a_{t+1}·λ_{t+1} (λ_{T-1} = g_{T-1}).
    # Flip time: λ'_k = gate'_k · λ'_{k-1} + g'_k with gate' = flip(a)
    # rolled one step right — the zero fill multiplies λ'_{-1} = 0, so
    # any fill is harmless. That is the SAME affine scan, so the
    # backward is one more launch of the forward's jitted engine kernel.
    gate = jnp.concatenate(
        [jnp.zeros_like(a[:, :1]), jnp.flip(a, 1)[:, :-1]], axis=1)
    lam = jnp.flip(
        _impl(gate, jnp.flip(g, 1), block_t, block_d, interpret, schedule),
        1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = (lam * h_prev).astype(a.dtype)
    return da, lam.astype(g.dtype)


_ssm_vjp.defvjp(_ssm_fwd, _ssm_bwd)


def ssm_scan(
    a: jax.Array,
    b: jax.Array,
    block_t: int = 256,
    block_d: int = 512,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> jax.Array:
    """Kernel-backed h_t = a_t ⊙ h_{t-1} + b_t over (B, T, D).

    Differentiable: the custom VJP runs the backward as one more engine
    affine scan over the flipped/rolled gates (see module doc).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if a.size == 0:
        # Degenerate (T, B or D == 0): the recurrence over nothing is
        # nothing; the block rounding below cannot tile an empty axis.
        return b
    schedule = resolved_schedule(a.shape, block_t, block_d, schedule)
    return _ssm_vjp(a, b, block_t, block_d, interpret, schedule)


# ---------------------------------------------------------------------------
# Back-compat kernel entry points (PR-1 signatures; 3D, pre-padded)
# ---------------------------------------------------------------------------


def _ssm_3d(a, b, block_t, block_d, interpret, schedule):
    if a.shape != b.shape or a.ndim != 3:
        raise ValueError(
            f"expect matching (B, T, D) inputs, got {a.shape} {b.shape}")
    B, T, D = a.shape
    layout = scan_engine.Channels(B, T, D, block_t, block_d)
    out, = scan_engine.scan(
        (a, b), monoids.AFFINE, layout, schedule=schedule,
        interpret=interpret)
    return out


def ssm_scan_kernel(a, b, *, block_t=256, block_d=512, interpret=False):
    """Carry-schedule affine scan of pre-padded (B, T, D) inputs."""
    return _ssm_3d(a, b, block_t, block_d, interpret, "carry")


def ssm_scan_decoupled(a, b, *, block_t=256, block_d=512, interpret=False):
    """Decoupled-schedule affine scan of pre-padded (B, T, D) inputs."""
    return _ssm_3d(a, b, block_t, block_d, interpret, "decoupled")
