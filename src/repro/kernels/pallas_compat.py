"""Version compat for the Pallas TPU API used by every kernel here.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat aliases differ across the 0.4.x / 0.5.x lines).  All kernel
packages build their ``compiler_params`` through this shim so a single
place tracks the drift.  The scan engine's fused single-launch schedule
additionally needs cross-chunk semaphores and an HBM/ANY memory space;
those are exposed here behind capability probes so the engine can fall
back to the two-launch decoupled schedule on jax versions (or backends)
without them.
"""

from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    _PARAMS_CLS = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    _PARAMS_CLS = pltpu.TPUCompilerParams
else:  # very old jax: pallas_call takes a plain dict
    _PARAMS_CLS = None


def compiler_params(*, dimension_semantics=None, **kw):
    """Build the TPU compiler-params object for ``pl.pallas_call``.

    ``dimension_semantics`` is a tuple of 'parallel' / 'arbitrary' strings,
    one per grid dimension (the knob every kernel in this repo sets).
    """
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    if _PARAMS_CLS is None:
        # pre-TPUCompilerParams jax keyed compiler params by backend
        return {"mosaic": dict(kw)}
    return _PARAMS_CLS(**kw)


# ---------------------------------------------------------------------------
# Semaphores + memory spaces (fused single-launch decoupled schedule)
# ---------------------------------------------------------------------------


def has_semaphores() -> bool:
    """Whether this jax exposes the TPU semaphore API the fused schedule
    chains chunks with (signal/wait + async copies + scratch sem arrays)."""
    return all(
        hasattr(pltpu, name)
        for name in ("SemaphoreType", "semaphore_signal", "semaphore_wait",
                     "make_async_copy")
    )


def regular_semaphores(shape):
    """A scratch array of regular (manually signaled) semaphores."""
    return pltpu.SemaphoreType.REGULAR(tuple(shape))


def dma_semaphore():
    return pltpu.SemaphoreType.DMA(())


def semaphore_signal(sem, inc=1):
    pltpu.semaphore_signal(sem, inc)


def semaphore_wait(sem, value=1):
    pltpu.semaphore_wait(sem, value)


def async_copy(src, dst, sem):
    """Start-and-return an async copy handle (``.start()`` / ``.wait()``)."""
    return pltpu.make_async_copy(src, dst, sem)


def any_memory_space():
    """The compiler-chosen (HBM-capable) memory space for unblocked refs."""
    if hasattr(pltpu, "ANY"):
        return pltpu.ANY
    return pl.ANY
