"""Version compat for the Pallas TPU API used by every kernel here.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat aliases differ across the 0.4.x / 0.5.x lines).  All four
kernel packages build their ``compiler_params`` through this shim so a
single place tracks the drift.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    _PARAMS_CLS = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    _PARAMS_CLS = pltpu.TPUCompilerParams
else:  # very old jax: pallas_call takes a plain dict
    _PARAMS_CLS = None


def compiler_params(*, dimension_semantics=None, **kw):
    """Build the TPU compiler-params object for ``pl.pallas_call``.

    ``dimension_semantics`` is a tuple of 'parallel' / 'arbitrary' strings,
    one per grid dimension (the knob every kernel in this repo sets).
    """
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    if _PARAMS_CLS is None:
        # pre-TPUCompilerParams jax keyed compiler params by backend
        return {"mosaic": dict(kw)}
    return _PARAMS_CLS(**kw)
