"""Jitted public wrapper for the stream-compaction kernel.

Handles arbitrary ranks (last-axis semantics like the cumsum wrappers),
padding to block multiples — padded positions carry mask 0, so they can
never emit a phantom destination — and interpret-mode fallback off TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compact.compact import mask_compact_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def _impl(mask, block_b, block_n, interpret):
    lead = mask.shape[:-1]
    n = mask.shape[-1]
    b = 1
    for d in lead:
        b *= d
    m2 = mask.reshape(b, n).astype(jnp.int32)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    bn = min(block_n, _round_up(n, 128))
    pad_n = (-n) % bn
    m2 = jnp.pad(m2, ((0, 0), (0, pad_n)))  # padded mask is 0: no phantoms

    dest, counts = mask_compact_kernel(
        m2, block_b=bb, block_n=bn, interpret=interpret)
    # Kernel sentinel is the PADDED length; remap to the caller's n so a
    # size-(n+1) scatter buffer parks every dropped element at index n.
    dest = jnp.minimum(dest[:, :n], n)
    return dest.reshape(lead + (n,)), counts.reshape(lead)


def mask_compact(
    mask: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
) -> tuple[jax.Array, jax.Array]:
    """Kernel-backed compaction indices along the last axis (any rank).

    Returns ``(dest, counts)`` with ``dest[..., i]`` the compacted write
    index where ``mask`` is nonzero and ``n`` (the axis length) where it
    is zero; ``counts[...]`` is the survivor count per row.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if mask.size == 0:  # zero-length axis OR zero-sized batch
        return (jnp.zeros(mask.shape, jnp.int32),
                jnp.zeros(mask.shape[:-1], jnp.int32))
    return _impl(mask, block_b, block_n, interpret)
