"""Stream compaction: the compact-mask registration of the scan engine.

Stream compaction (filter) is the paper's §1 database use case: the new
index of every surviving element is the exclusive prefix sum of the
keep-mask at its position. The mask monoid
(``core/scan/assoc.mask_kernel_spec``) is integer SUM with the predicate
select FUSED into the writeback — surviving lanes emit their global
destination, dropped lanes emit the sentinel — so the output feeds an
XLA scatter directly, under ANY of the engine's three schedules.

The wrapper handles arbitrary ranks (last-axis semantics like the cumsum
wrappers), padding to block multiples — padded positions carry mask 0, so
they can never emit a phantom destination — and interpret-mode fallback
off TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import scan_engine
from repro.kernels.scan_engine import monoids, resolve_schedule


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret", "schedule"))
def _impl(mask, block_b, block_n, interpret, schedule):
    lead = mask.shape[:-1]
    n = mask.shape[-1]
    b = 1
    for d in lead:
        b *= d
    # Normalize BEFORE the int cast: a fractional float mask value (0.5)
    # is "keep" per the nonzero contract; astype alone would drop it.
    m2 = (mask.reshape(b, n) != 0).astype(jnp.int32)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    bn = min(block_n, _round_up(n, 128))
    pad_n = (-n) % bn
    m2 = jnp.pad(m2, ((0, 0), (0, pad_n)))  # padded mask is 0: no phantoms

    layout = scan_engine.Rows(m2.shape[0], m2.shape[1], bb, bn)
    (dest,), (totals,) = scan_engine.scan(
        (m2,), monoids.mask(m2.shape[1]), layout, schedule=schedule,
        interpret=interpret, return_totals=True)
    # Survivor counts from the O(B·chunks) running chunk-totals chain the
    # kernel already maintains — its last column is the row total (exact
    # integers, identical bits under every schedule; padded positions are
    # 0 so they never count). No second read-n reduction over the mask.
    counts = totals[:, -1].astype(jnp.int32)
    # Kernel sentinel is the PADDED length; remap to the caller's n so a
    # size-(n+1) scatter buffer parks every dropped element at index n.
    dest = jnp.minimum(dest[:, :n], n)
    return dest.reshape(lead + (n,)), counts.reshape(lead)


def mask_compact(
    mask: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Kernel-backed compaction indices along the last axis (any rank).

    Returns ``(dest, counts)`` with ``dest[..., i]`` the compacted write
    index where ``mask`` is nonzero and ``n`` (the axis length) where it
    is zero; ``counts[...]`` is the survivor count per row.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if mask.size == 0:  # zero-length axis OR zero-sized batch
        return (jnp.zeros(mask.shape, jnp.int32),
                jnp.zeros(mask.shape[:-1], jnp.int32))
    n = mask.shape[-1]
    batch = max(mask.size // max(n, 1), 1)
    bn = min(block_n, _round_up(n, 128))  # the block _impl uses
    schedule = resolve_schedule(schedule, batch, n, bn)
    return _impl(mask, block_b, block_n, interpret, schedule)


def mask_compact_kernel(mask, *, block_b=8, block_n=2048, interpret=False,
                        schedule="decoupled"):
    """Back-compat PR-2 entry point: pre-padded 2D (B, N) masks only."""
    if mask.ndim != 2:
        raise ValueError(f"kernel expects 2D input, got {mask.shape}")
    mask = (mask != 0).astype(jnp.int32)
    layout = scan_engine.Rows(mask.shape[0], mask.shape[1], block_b, block_n)
    dest, = scan_engine.scan(
        (mask,), monoids.mask(mask.shape[1]), layout, schedule=schedule,
        interpret=interpret)
    counts = jnp.sum(mask, axis=-1, dtype=jnp.int32)
    return dest, counts
