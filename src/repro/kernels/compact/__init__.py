from repro.kernels.compact.ops import mask_compact

__all__ = ["mask_compact"]
