from repro.kernels.compact.ops import mask_compact, mask_compact_kernel

__all__ = ["mask_compact", "mask_compact_kernel"]
