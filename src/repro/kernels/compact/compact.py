"""Fused Pallas stream-compaction kernel: keep-mask -> scatter destinations.

Stream compaction (filter) is the paper's §1 database use case: the new
index of every surviving element is the exclusive prefix sum of the
keep-mask at its position. This kernel computes those indices with the
PR-1 *decoupled reduce-then-scan* schedule (see
``kernels/scan_blocked/decoupled.py``) applied to the mask scan:

  pass 1b  fully parallel grid over (row-block, chunk): each instance
           reduces its mask chunk to a survivor COUNT (via the same
           in-block scan network as the cumsum kernels, so the
           association order matches the carry chain exactly).
  combine  a tiny sequential exclusive scan over the (B, chunks) counts
           — each chunk's base write offset.
  pass 2   fully parallel grid: redo the in-chunk exclusive mask scan,
           add the chunk offset, and FUSE the predicate select into the
           writeback: surviving lanes emit their global destination,
           dropped lanes emit the sentinel. The output feeds an XLA
           scatter directly — no separate where/select pass over n.

Both grids are ``("parallel", "parallel")``: a single long mask row
spreads across every core, exactly like the decoupled cumsum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params
from repro.kernels.scan_blocked.decoupled import _exclusive_chain
from repro.kernels.scan_blocked.scan_blocked import _inblock_scan


def _totals_kernel(m_ref, tot_ref):
    """Pass 1b: survivors per chunk, via the in-block scan's last column."""
    m = m_ref[...].astype(jnp.int32)
    tot_ref[...] = _inblock_scan(m)[:, -1:]


def _dest_kernel(m_ref, off_ref, dest_ref, *, sentinel):
    """Pass 2: exclusive in-chunk mask scan + chunk offset + fused select."""
    m = m_ref[...].astype(jnp.int32)
    inc = _inblock_scan(m)
    dest = inc - m + off_ref[...]  # exclusive scan of a 0/1 mask, offset
    dest_ref[...] = jnp.where(m != 0, dest, sentinel)


def mask_compact_kernel(
    mask: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Scatter destinations for a 2D (B, N) 0/1 mask.

    Returns ``(dest, counts)``: ``dest[b, i]`` is the compacted write
    index of element ``i`` when kept and the sentinel ``N`` when dropped;
    ``counts[b]`` is the number of survivors per row. Same caller
    contract as the cumsum kernels: shape divisible by the block.
    """
    if mask.ndim != 2:
        raise ValueError(f"kernel expects 2D input, got {mask.shape}")
    B, N = mask.shape
    if B % block_b or N % block_n:
        raise ValueError(
            f"shape {mask.shape} not divisible by block ({block_b}, {block_n})"
        )
    mask = mask.astype(jnp.int32)
    chunks = N // block_n
    grid = (B // block_b, chunks)
    mspec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))
    tspec = pl.BlockSpec((block_b, 1), lambda i, j: (i, j))

    totals = pl.pallas_call(
        _totals_kernel,
        grid=grid,
        in_specs=[mspec],
        out_specs=tspec,
        out_shape=jax.ShapeDtypeStruct((B, chunks), jnp.int32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="mask_compact_totals",
    )(mask)

    offsets = _exclusive_chain(totals)
    counts = offsets[:, -1] + totals[:, -1]

    dest = pl.pallas_call(
        functools.partial(_dest_kernel, sentinel=N),
        grid=grid,
        in_specs=[mspec, tspec],
        out_specs=mspec,
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="mask_compact_dest",
    )(mask, offsets)
    return dest, counts
