"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro import configs
from repro.serve import Engine, EngineConfig, Request
from repro.train.step import init_params


def main():
    cfg = configs.get_smoke_config("gemma2-9b")  # SWA + softcap family
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(
        max_slots=4, max_len=96, max_new_tokens=24, temperature=0.7,
        top_p=0.9, eos_id=-1))

    rng = np.random.default_rng(0)
    n_req = 10
    t0 = time.perf_counter()
    for rid in range(n_req):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32)))
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    eng.audit()  # lifecycle invariants: one finish reason each, none lost
    total = sum(len(r.output) for r in done)
    print(f"{len(done)} requests · {total} tokens · {dt:.1f}s "
          f"({total/dt:.1f} tok/s through {eng.ecfg.max_slots} slots)")
    print(f"stats: {eng.stats.summary()}")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req{r.rid}: [{r.finish_reason}] {len(r.output)} tokens "
              f"-> {r.output[:8]}…")


if __name__ == "__main__":
    main()
