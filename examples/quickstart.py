"""Quickstart: the scan substrate in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scanlib


def main():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1 << 16),
                    jnp.float32)

    # 1. Policy-picked prefix sum (paper §5 recommendations).
    y = scanlib.cumsum(x)
    print("cumsum ok:", np.allclose(np.asarray(y),
                                    np.cumsum(np.asarray(x)), atol=1e-2))

    # 2. Every algorithm from the paper, same API.
    for algo in ("horizontal", "vertical", "tree", "blocked", "two_pass"):
        z = scanlib.scan(x, "sum", algorithm=algo)
        assert np.allclose(np.asarray(z), np.asarray(y), atol=1e-2), algo
    print("all 5 paper algorithms agree")

    # 3. Generalized monoids: the SSM recurrence h' = a*h + b is a scan.
    a = jnp.full((1024,), 0.9, jnp.float32)
    b = jnp.ones((1024,), jnp.float32)
    _, h = scanlib.scan((a, b), "affine", algorithm="blocked")
    print("affine scan steady state ~10:", float(h[-1]))

    # 4. The paper's database use case: partitioning offsets.
    ids = jnp.asarray([2, 0, 1, 2, 2, 0], jnp.int32)
    plan = scanlib.dispatch_offsets(ids, num_experts=3)
    print("histogram:", plan.counts, "offsets:", plan.offsets,
          "dest:", plan.dest)

    # 5. Pallas TPU kernel (interpret mode on CPU).
    xk = x.reshape(8, -1)
    yk = scanlib.scan(xk, "sum", axis=-1, algorithm="kernel",
                      interpret=True)
    print("kernel ok:", np.allclose(np.asarray(yk),
                                    np.cumsum(np.asarray(xk), -1),
                                    atol=1e-2))


if __name__ == "__main__":
    main()
