"""A small relational query plan on the scan substrate.

    PYTHONPATH=src python examples/table_queries.py

The SQL being evaluated, entirely through prefix-sum operators
(``repro.relational``):

    SELECT c.region, SUM(o.amount)
    FROM   orders o JOIN customers c ON o.cust_id = c.cust_id
    WHERE  o.amount >= 50
    GROUP BY c.region;

filter   -> relational.filter_compact   (mask cumsum -> gather)
join     -> relational.hash_join        (scan-built build/probe offsets)
group-by -> relational.group_by         (partition + segmented scan)
"""

import jax.numpy as jnp
import numpy as np

from repro import relational as rel

NUM_REGIONS = 4
REGION_NAMES = ["north", "south", "east", "west"]


def main():
    rng = np.random.default_rng(42)

    # customers(cust_id, region); orders(cust_id, amount)
    n_cust, n_ord = 32, 200
    cust_id = jnp.arange(n_cust, dtype=jnp.int32)
    region = jnp.asarray(rng.integers(0, NUM_REGIONS, n_cust), jnp.int32)
    o_cust = jnp.asarray(rng.integers(0, n_cust, n_ord), jnp.int32)
    amount = jnp.asarray(rng.integers(1, 100, n_ord), jnp.int32)

    # WHERE amount >= 50 — stream compaction
    mask = amount >= 50
    f_cust, n_kept = rel.filter_compact(o_cust, mask)
    f_amt, _ = rel.filter_compact(amount, mask)
    n_kept = int(n_kept)
    f_cust, f_amt = f_cust[:n_kept], f_amt[:n_kept]
    print(f"filter: kept {n_kept}/{n_ord} orders")

    # JOIN ON o.cust_id = c.cust_id — partitioned hash join
    pairs = rel.hash_join(f_cust, cust_id)
    n_pairs = int(pairs.count)
    li = pairs.left_index[:n_pairs]
    ri = pairs.right_index[:n_pairs]
    print(f"join: {n_pairs} matched rows")

    # GROUP BY region, SUM(amount) — partition + segmented scan
    totals = rel.group_by(region[ri], f_amt[li], NUM_REGIONS, agg="sum")
    counts = rel.group_by(region[ri], f_amt[li], NUM_REGIONS, agg="count")

    # numpy reference: the same query, nested loops
    want = np.zeros(NUM_REGIONS, np.int64)
    for c, a in zip(np.asarray(o_cust), np.asarray(amount)):
        if a >= 50:
            want[int(region[c])] += a
    np.testing.assert_array_equal(np.asarray(totals, np.int64), want)

    print(f"\n{'region':<8}{'orders':>8}{'total':>8}")
    for r in range(NUM_REGIONS):
        print(f"{REGION_NAMES[r]:<8}{int(counts[r]):>8}{int(totals[r]):>8}")
    print("\nquery plan result matches numpy reference")


if __name__ == "__main__":
    main()
