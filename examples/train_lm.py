"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the granite-moe family at reduced-but-real scale (~100M params) so
the MoE scan-dispatch path — the paper's technique inside the model — is
exercised end to end with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import logging

import jax

from repro import configs
from repro.data import DataConfig, SyntheticDataset
from repro.optim import adamw_init
from repro.train.step import TrainStepConfig, init_params, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def build_cfg():
    """~100M-param MoE config of the granite family."""
    base = configs.get_config("granite-moe-1b-a400m")
    return dataclasses.replace(
        base, name="granite-moe-100m", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=512, moe_d_ff=512,
        vocab_size=32_000, num_experts=8, top_k=2, max_seq_len=1024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = build_cfg()
    n = cfg.param_count()
    print(f"model: {cfg.name}  params≈{n/1e6:.0f}M "
          f"(active {cfg.active_param_count()/1e6:.0f}M)")

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    step = jax.jit(
        make_train_step(cfg, TrainStepConfig(
            remat=True, peak_lr=1e-3, warmup_steps=20,
            total_steps=args.steps)),
        donate_argnums=(0, 1))
    ds = SyntheticDataset(DataConfig(
        seq_len=args.seq_len, global_batch=args.batch,
        vocab_size=cfg.vocab_size))
    tr = Trainer(step, ds, TrainerConfig(
        total_steps=args.steps, checkpoint_every=50,
        checkpoint_dir=args.ckpt, log_every=10))
    start, params, opt = tr.maybe_restore(params, opt)
    tr.run(params, opt, start_step=start)

    losses = [h["loss"] for h in tr.history]
    k = max(len(losses) // 10, 1)
    print(f"loss: first10={sum(losses[:k])/k:.4f} "
          f"last10={sum(losses[-k:])/k:.4f} "
          f"(decreased: {sum(losses[-k:]) < sum(losses[:k])})")


if __name__ == "__main__":
    main()
