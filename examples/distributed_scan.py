"""The paper's multithreaded two-pass scan across devices (shard_map).

Runs on 8 placeholder CPU devices; the same code drives the 256-chip
mesh. Shows variants 1/2 and the three carry-exchange schedules with
their collective footprints.

    PYTHONPATH=src python examples/distributed_scan.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
import numpy as np                                                # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P        # noqa: E402

from repro.core import scan as scanlib                            # noqa: E402
from repro.roofline.analyze import collective_bytes_from_hlo      # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("d",))
    n = 1 << 20
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    sh = NamedSharding(mesh, P("d"))
    xs = jax.device_put(x, sh)
    ref = np.cumsum(np.asarray(x), dtype=np.float64)

    for variant in (1, 2):
        for exchange in ("all_gather", "hillis_permute", "ring"):
            fn = jax.jit(lambda v: scanlib.scan_sharded(
                v, "sum", mesh=mesh, axis_name="d", spec=P("d"),
                variant=variant, carry_exchange=exchange,
                local_algorithm="blocked", block_size=1 << 16))
            y = fn(xs)
            ok = np.allclose(np.asarray(y), ref, rtol=1e-3, atol=1e-1)
            coll = collective_bytes_from_hlo(
                fn.lower(xs).compile().as_text())
            total = sum(coll.values())
            print(f"variant={variant} exchange={exchange:<14} ok={ok} "
                  f"collective_bytes={total}")

    # The affine monoid (SSM sequence parallelism) over the same machinery.
    a = jnp.asarray(np.random.default_rng(1).uniform(0.9, 1.0, n),
                    jnp.float32)
    b = jnp.asarray(np.random.default_rng(2).standard_normal(n), jnp.float32)
    _, h = scanlib.scan_sharded(
        (jax.device_put(a, sh), jax.device_put(b, sh)), "affine",
        mesh=mesh, axis_name="d", spec=P("d"),
        carry_exchange="hillis_permute", local_algorithm="ref")
    print("distributed affine scan final state:", float(h[-1]))


if __name__ == "__main__":
    main()
